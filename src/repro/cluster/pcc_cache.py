"""PCCCache — the paper's "past observed" allocation path, refined online.

A query seen before needs no model: its exact PCC is fitted from the
observed skyline of its own production run. The cache maps query identity
(the trace's unique-query index) to exact power-law parameters (a, b); the
cluster simulator populates it from completed queries, so the cache warms as
traffic repeats and repeat queries bypass the learned model entirely.

Refinement is fully batched: completed skylines are padded into one
(B, Smax) matrix, AREPAS-simulated at the standard allocation grid in one
jitted ``simulate_runtime_batch`` call, and the grid is fitted with the
vectorized float64 ``fit_pcc_batch_np`` — the same math the training set
uses (``core/dataset.py``), so a cache entry is the exact-history fit.

Staleness: recurring templates drift (the same script over a fresh, larger
day of data). Each entry remembers the skyline area (total work) it was
fitted from; a lookup that passes the query's *current* area demotes an
entry whose cached area drifted beyond ``drift_tol`` to a miss and evicts
it, so the completion path refits the curve instead of serving the stale
one. ``max_entries`` bounds the table with LRU eviction.

``ShardedPCCCache`` spreads the table over K shards by query-template hash
(the ``Router``'s home assignment): each shard warms only its own slice of
the template population, and the sharded fabric's cache-affinity routing
keeps repeat traffic on the shard that already holds its exact PCC. The
single-shard ``PCCCache`` is its K=1 unit, not a separate code path.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.arepas import simulate_runtime_batch_jit
from repro.core.dataset import PCC_FRACTIONS
from repro.core.pcc import fit_pcc_batch_np
from repro.serve.batching import batch_bucket, pad_to

__all__ = ["PCCCache", "ShardedPCCCache"]


class PCCCache:
    """Exact per-query PCC parameters keyed by unique-query id."""

    def __init__(self, fractions: Sequence[float] = PCC_FRACTIONS,
                 max_entries: Optional[int] = None,
                 drift_tol: float = 0.25):
        self.fractions = np.asarray(sorted(fractions, reverse=True),
                                    np.float64)
        assert np.all(self.fractions > 0)
        assert max_entries is None or max_entries >= 1
        self.max_entries = max_entries
        self.drift_tol = drift_tol
        # the serving model's version at refine time; a hot-swap bumps it
        # (``bump_model_version``) and lookups demote older entries — a
        # post-swap cache hit can never serve a curve refined under the
        # retired model
        self.model_version = 0
        # one dict so (a, b, area, version) can never desynchronize
        self._entries: Dict[int, Tuple[float, float, float, float]] = {}
        self._used: Dict[int, int] = {}       # LRU tick per key
        self._tick = 0
        self._dense = None         # (keys, a, b, area, version) sorted view
        self.stats = {"hits": 0, "misses": 0, "refined": 0, "refine_calls": 0,
                      "stale": 0, "evicted": 0, "dense_rebuilds": 0,
                      "version_stale": 0}

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: int) -> bool:
        return int(key) in self._entries

    def _dense_view(self) -> Tuple[np.ndarray, ...]:
        """Sorted columnar view of the table, rebuilt lazily on mutation —
        lookups are pure numpy gathers, no per-key Python in the hot path.
        ``None`` is the dirty flag: only refine/evict clear it, so the
        sharded hot path (K caches probed every epoch) never re-densifies a
        shard whose entries did not change. ``stats["dense_rebuilds"]``
        counts real rebuilds (regression-tested)."""
        if self._dense is None:
            self.stats["dense_rebuilds"] += 1
            n = len(self._entries)
            keys = np.fromiter(self._entries.keys(), np.int64, n)
            vals = np.array(list(self._entries.values()),
                            np.float64).reshape(n, 4)
            order = np.argsort(keys)
            self._dense = (keys[order], vals[order, 0], vals[order, 1],
                           vals[order, 2], vals[order, 3])
        return self._dense

    def _find(self, keys: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(hit mask, dense row index) per key — vectorized searchsorted."""
        dk = self._dense_view()[0]
        idx = np.searchsorted(dk, keys)
        idx = np.minimum(idx, max(dk.size - 1, 0))
        hit = (dk[idx] == keys) if dk.size else np.zeros(keys.size, bool)
        return hit, idx

    def missing(self, keys: np.ndarray) -> np.ndarray:
        """(K,) bool: key has no cache entry (vectorized, no stats)."""
        hit, _ = self._find(np.asarray(keys, np.int64))
        return ~hit

    def _evict(self, key: int) -> None:
        del self._entries[key], self._used[key]
        self._dense = None
        self.stats["evicted"] += 1

    def bump_model_version(self, version: Optional[int] = None) -> int:
        """A model hot-swap happened: entries refined under the old model
        become stale (next lookup demotes them to misses and evicts, so
        the completion path refits them under the new regime)."""
        self.model_version = int(version) if version is not None \
            else self.model_version + 1
        return self.model_version

    # -------------------------------------------------------------- lookup --
    def lookup(self, keys: np.ndarray, areas: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batch lookup: (hit mask, a, b); (a, b) are 0 where missed.

        ``areas`` — the queries' current skyline areas — enables drift
        detection: an entry fitted from work that differs from the current
        volume by more than ``drift_tol`` (relative) is stale, counts as a
        miss, and is evicted so the next completion refits it.
        """
        keys = np.asarray(keys, np.int64)
        hit, idx = self._find(keys)
        if self.model_version and np.any(hit):
            ver = np.where(hit, self._dense_view()[4][idx],
                           self.model_version)
            vstale = hit & (ver < self.model_version)
            if np.any(vstale):
                self.stats["version_stale"] += int(vstale.sum())
                for k in np.unique(keys[vstale]):
                    self._evict(int(k))
                hit, idx = self._find(keys)
        if areas is not None and np.any(hit):
            cached = np.where(hit, self._dense_view()[3][idx], 0.0)
            cur = np.asarray(areas, np.float64)
            stale = hit & (np.abs(cur - cached)
                           > self.drift_tol * np.maximum(cached, 1e-9))
            if np.any(stale):
                self.stats["stale"] += int(stale.sum())
                for k in np.unique(keys[stale]):
                    self._evict(int(k))
                # re-resolve from scratch: eviction removes the key for
                # *every* row that references it (a duplicate key with a
                # fresh area must not resolve to a neighboring entry)
                hit, idx = self._find(keys)
        _, da, db, _, _ = self._dense_view()
        a = np.where(hit, da[idx] if da.size else 0.0, 0.0)
        b = np.where(hit, db[idx] if db.size else 0.0, 0.0)
        self._tick += 1
        if self.max_entries is not None and np.any(hit):   # LRU bookkeeping
            self._used.update(
                dict.fromkeys(np.unique(keys[hit]).tolist(), self._tick))
        self.stats["hits"] += int(hit.sum())
        self.stats["misses"] += int((~hit).sum())
        return hit, a, b

    # ---------------------------------------------------------- refinement --
    def refine_batch(self, keys: np.ndarray, skylines: np.ndarray,
                     valid_lens: np.ndarray, observed_tokens: np.ndarray,
                     peaks: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Fit exact PCCs for a batch of completed queries and cache them.

        skylines: (B, Smax) padded observed skylines; valid_lens: (B,) true
        lengths (== observed runtimes); observed_tokens/peaks: (B,) the run's
        allocation and peak usage. Returns the fitted (a, b) arrays.

        Keys already refined are refitted — drifted reruns of a recurring
        template overwrite the stale curve with the fresh one (the executor
        is deterministic, so a refit from identical data is identical).
        """
        keys = np.asarray(keys, np.int64)
        B = keys.shape[0]
        if B == 0:
            return np.zeros(0), np.zeros(0)
        self.stats["refine_calls"] += 1

        obs = np.asarray(observed_tokens, np.float64)
        allocs = np.maximum(1, np.round(self.fractions[None, :] * obs[:, None])
                            ).astype(np.int64)                       # (B, K)
        base_rt = np.asarray(valid_lens, np.int64)

        # one jitted AREPAS call over the padded batch (bucketed so repeat
        # traffic reuses a bounded set of compiled shapes)
        Bp = batch_bucket(B)
        sim_rt = np.asarray(simulate_runtime_batch_jit(
            jnp.asarray(pad_to(np.asarray(skylines, np.float32), Bp)),
            jnp.asarray(pad_to(np.asarray(valid_lens, np.int32), Bp)),
            jnp.asarray(np.maximum(pad_to(allocs, Bp), 1))))[:B]     # (B, K)

        # at/above the observed peak the skyline cannot change (§4.4 floor)
        runtimes = np.where(allocs >= np.asarray(peaks, np.int64)[:, None],
                            base_rt[:, None], sim_rt)
        runtimes = np.maximum(runtimes, 1)

        a, b = fit_pcc_batch_np(allocs, runtimes)
        a = np.minimum(a, -1e-4)      # deterministic runs are monotone
        row_area = np.asarray(skylines, np.float64).sum(axis=1)
        self._tick += 1
        for i, (k, ai, bi) in enumerate(zip(keys, a, b)):
            if int(k) not in self._entries:
                self.stats["refined"] += 1
            self._entries[int(k)] = (float(ai), float(bi), float(row_area[i]),
                                     float(self.model_version))
            self._used[int(k)] = self._tick
        self._dense = None
        if self.max_entries is not None and len(self._entries) > self.max_entries:
            by_age = sorted(self._used, key=self._used.get)
            for k in by_age[:len(self._entries) - self.max_entries]:
                self._evict(int(k))
        return a, b


class ShardedPCCCache:
    """K per-shard ``PCCCache`` units addressed by precomputed shard ranks.

    The caller (the simulator / serving fabric) routes once per batch —
    ``shard_of = router.rank(router.home(keys))`` — and every cache
    operation takes that (N,) rank vector alongside the keys, grouping rows
    per shard and delegating to the owning unit. Results come back in input
    order. K=1 degenerates to a single ``PCCCache`` fed whole batches.
    """

    def __init__(self, n_shards: int = 1, **cache_kwargs):
        assert n_shards >= 1
        self.n_shards = int(n_shards)
        self.shards = [PCCCache(**cache_kwargs) for _ in range(n_shards)]

    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    def bump_model_version(self, version: Optional[int] = None) -> int:
        """Propagate a model hot-swap to every shard's cache."""
        return max(s.bump_model_version(version) for s in self.shards)

    @property
    def stats(self) -> Dict[str, int]:
        """Fabric-wide stats: the per-shard counters summed."""
        out: Dict[str, int] = dict.fromkeys(self.shards[0].stats, 0)
        for s in self.shards:
            for k, v in s.stats.items():
                out[k] += v
        return out

    def _grouped(self, shard_of: np.ndarray):
        shard_of = np.asarray(shard_of, np.int64)
        if self.n_shards == 1:
            yield 0, slice(None)
            return
        for s in np.unique(shard_of):
            yield int(s), shard_of == s

    def lookup(self, shard_of: np.ndarray, keys: np.ndarray,
               areas: Optional[np.ndarray] = None
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batch lookup across shards: (hit mask, a, b) in input order."""
        keys = np.asarray(keys, np.int64)
        hit = np.zeros(keys.size, bool)
        a = np.zeros(keys.size, np.float64)
        b = np.zeros(keys.size, np.float64)
        for s, m in self._grouped(shard_of):
            hit[m], a[m], b[m] = self.shards[s].lookup(
                keys[m], None if areas is None else np.asarray(areas)[m])
        return hit, a, b

    def missing(self, shard_of: np.ndarray, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, np.int64)
        out = np.ones(keys.size, bool)
        for s, m in self._grouped(shard_of):
            out[m] = self.shards[s].missing(keys[m])
        return out

    def refine_batch(self, shard_of: np.ndarray, keys: np.ndarray,
                     skylines: np.ndarray, valid_lens: np.ndarray,
                     observed_tokens: np.ndarray, peaks: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Fit and cache exact PCCs, each key on its home shard."""
        keys = np.asarray(keys, np.int64)
        a = np.zeros(keys.size, np.float64)
        b = np.zeros(keys.size, np.float64)
        for s, m in self._grouped(shard_of):
            a[m], b[m] = self.shards[s].refine_batch(
                keys[m], np.asarray(skylines)[m], np.asarray(valid_lens)[m],
                np.asarray(observed_tokens)[m], np.asarray(peaks)[m])
        return a, b
