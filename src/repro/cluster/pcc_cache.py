"""PCCCache — the paper's "past observed" allocation path, refined online.

A query seen before needs no model: its exact PCC is fitted from the
observed skyline of its own production run. The cache maps query identity
(the trace's unique-query index) to exact power-law parameters (a, b); the
cluster simulator populates it from completed queries, so the cache warms as
traffic repeats and repeat queries bypass the learned model entirely.

Refinement is fully batched: completed skylines are padded into one
(B, Smax) matrix, AREPAS-simulated at the standard allocation grid in one
jitted ``simulate_runtime_batch`` call, and the grid is fitted with the
vectorized float64 ``fit_pcc_batch_np`` — the same math the training set
uses (``core/dataset.py``), so a cache entry is the exact-history fit.
"""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.arepas import simulate_runtime_batch_jit
from repro.core.dataset import PCC_FRACTIONS
from repro.core.pcc import fit_pcc_batch_np
from repro.serve.batching import batch_bucket, pad_to

__all__ = ["PCCCache"]


class PCCCache:
    """Exact per-query PCC parameters keyed by unique-query id."""

    def __init__(self, fractions: Sequence[float] = PCC_FRACTIONS):
        self.fractions = np.asarray(sorted(fractions, reverse=True),
                                    np.float64)
        assert np.all(self.fractions > 0)
        self._a: Dict[int, float] = {}
        self._b: Dict[int, float] = {}
        self.stats = {"hits": 0, "misses": 0, "refined": 0, "refine_calls": 0}

    def __len__(self) -> int:
        return len(self._a)

    def __contains__(self, key: int) -> bool:
        return int(key) in self._a

    # -------------------------------------------------------------- lookup --
    def lookup(self, keys: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batch lookup: (hit mask, a, b); (a, b) are 0 where missed."""
        keys = np.asarray(keys, np.int64)
        hit = np.array([int(k) in self._a for k in keys], bool)
        a = np.array([self._a.get(int(k), 0.0) for k in keys], np.float64)
        b = np.array([self._b.get(int(k), 0.0) for k in keys], np.float64)
        self.stats["hits"] += int(hit.sum())
        self.stats["misses"] += int((~hit).sum())
        return hit, a, b

    # ---------------------------------------------------------- refinement --
    def refine_batch(self, keys: np.ndarray, skylines: np.ndarray,
                     valid_lens: np.ndarray, observed_tokens: np.ndarray,
                     peaks: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Fit exact PCCs for a batch of completed queries and cache them.

        skylines: (B, Smax) padded observed skylines; valid_lens: (B,) true
        lengths (== observed runtimes); observed_tokens/peaks: (B,) the run's
        allocation and peak usage. Returns the fitted (a, b) arrays.

        Keys already refined are refitted idempotently (the executor is
        deterministic, so the fit is identical); callers typically filter.
        """
        keys = np.asarray(keys, np.int64)
        B = keys.shape[0]
        if B == 0:
            return np.zeros(0), np.zeros(0)
        self.stats["refine_calls"] += 1

        obs = np.asarray(observed_tokens, np.float64)
        allocs = np.maximum(1, np.round(self.fractions[None, :] * obs[:, None])
                            ).astype(np.int64)                       # (B, K)
        base_rt = np.asarray(valid_lens, np.int64)

        # one jitted AREPAS call over the padded batch (bucketed so repeat
        # traffic reuses a bounded set of compiled shapes)
        Bp = batch_bucket(B)
        sim_rt = np.asarray(simulate_runtime_batch_jit(
            jnp.asarray(pad_to(np.asarray(skylines, np.float32), Bp)),
            jnp.asarray(pad_to(np.asarray(valid_lens, np.int32), Bp)),
            jnp.asarray(np.maximum(pad_to(allocs, Bp), 1))))[:B]     # (B, K)

        # at/above the observed peak the skyline cannot change (§4.4 floor)
        runtimes = np.where(allocs >= np.asarray(peaks, np.int64)[:, None],
                            base_rt[:, None], sim_rt)
        runtimes = np.maximum(runtimes, 1)

        a, b = fit_pcc_batch_np(allocs, runtimes)
        a = np.minimum(a, -1e-4)      # deterministic runs are monotone
        for k, ai, bi in zip(keys, a, b):
            if int(k) not in self._a:
                self.stats["refined"] += 1
            self._a[int(k)] = float(ai)
            self._b[int(k)] = float(bi)
        return a, b
