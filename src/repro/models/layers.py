"""Model-zoo building blocks (pure functions over param dicts).

Conventions:
  * activations: (batch, seq, ...) with compute dtype from the config;
    softmax / norms / RoPE accumulate in float32.
  * every block takes a ``shard`` callable (repro.models.params.Sharder) that
    applies logical-axis sharding constraints; NULL_SHARDER makes it a no-op.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------- norms ----
def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * weight


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dtype) * weight + bias


# ----------------------------------------------------------------- RoPE ----
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32. Rotate-half convention."""
    freqs = rope_freqs(x.shape[-1], theta)                      # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: Tuple[int, int, int]
) -> jax.Array:
    """M-RoPE (qwen2-vl): positions (3, B, S) = (temporal, height, width) ids.

    The D/2 frequency slots are split into three contiguous sections, each
    rotated by its own position component.
    """
    d_half = x.shape[-1] // 2
    assert sum(sections) == d_half, (sections, d_half)
    freqs = rope_freqs(x.shape[-1], theta)                      # (D/2,)
    # angles per component: (3, B, S, D/2)
    angles = positions[..., None].astype(jnp.float32) * freqs
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=d_half)
    angle = jnp.take_along_axis(
        jnp.moveaxis(angles, 0, -1),                            # (B, S, D/2, 3)
        sec_id[None, None, :, None].astype(jnp.int32),
        axis=-1,
    )[..., 0]                                                   # (B, S, D/2)
    cos = jnp.cos(angle)[:, :, None, :]
    sin = jnp.sin(angle)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----
def _gqa_scores_softmax_out(q, k, v, mask, scale):
    """Dense masked attention core. q:(B,Sq,Hq,D) k/v:(B,Sk,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, Hq, D)


def causal_attention_ref(q, k, v, chunk_q: int = 512) -> jax.Array:
    """Masked-dense causal attention, scanned over query chunks.

    Baseline XLA path: computes the full S^2 score matrix chunk-by-chunk
    (working set O(chunk_q * S)); masked blocks are computed then discarded
    (2x the causal-optimal FLOPs — see causal_attention_tri for the
    triangle-decomposed optimal version, and kernels/flash_attention.py for
    the TPU kernel that skips them structurally).
    """
    B, S, Hq, D = q.shape
    scale = 1.0 / math.sqrt(D)
    if S <= chunk_q:
        mask = jnp.tril(jnp.ones((S, S), jnp.bool_))[None, None, None]
        return _gqa_scores_softmax_out(q, k, v, mask, scale)
    assert S % chunk_q == 0, (S, chunk_q)
    nq = S // chunk_q
    qs = q.reshape(B, nq, chunk_q, Hq, D).transpose(1, 0, 2, 3, 4)

    def body(carry, args):
        i, qc = args
        rows = i * chunk_q + jnp.arange(chunk_q)
        mask = rows[:, None] >= jnp.arange(S)[None, :]
        out = _gqa_scores_softmax_out(qc, k, v, mask[None, None, None], scale)
        return carry, out

    _, outs = jax.lax.scan(body, None, (jnp.arange(nq), qs))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, Hq, D)


def _attn_block_stats(q, k, v, mask, scale):
    """Flash-style block attention: returns UNNORMALIZED (num, m, l).

    num: (B,Sq,Hq,D) = sum_k exp(s - m) * v;  m/l: (B,Sq,Hq) row max / denom.
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    num = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v).reshape(B, Sq, Hq, D)
    to_bshq = lambda t: t.transpose(0, 3, 1, 2).reshape(B, Sq, Hq)
    return num.astype(jnp.float32), to_bshq(m), to_bshq(l)


def _merge_stats(a, b):
    (na, ma, la), (nb, mb, lb) = a, b
    m = jnp.maximum(ma, mb)
    wa = jnp.exp(ma - m)
    wb = jnp.exp(mb - m)
    return (na * wa[..., None] + nb * wb[..., None], m, la * wa + lb * wb)


def causal_attention_tri(q, k, v, depth: int = 3, leaf_chunk: int = 512) -> jax.Array:
    """Triangle-decomposed causal attention (FLOP-optimal up to 2^-depth waste).

    T(S) = two half-triangles + one UNMASKED dense (S/2 x S/2) block. Each
    recursion level halves the masked-block waste of the dense baseline; at
    depth d the waste is 2^-d. Results are combined with exact flash-style
    log-sum-exp merging (bitwise-equivalent math, not an approximation).
    """
    scale = 1.0 / math.sqrt(q.shape[-1])

    def tri(qc, kc, vc, d):
        Sc = qc.shape[1]
        if d == 0 or Sc <= leaf_chunk:
            mask = jnp.arange(Sc)[:, None] >= jnp.arange(Sc)[None, :]
            return _attn_block_stats(qc, kc, vc, mask[None, None, None], scale)
        h = Sc // 2
        n1, m1, l1 = tri(qc[:, :h], kc[:, :h], vc[:, :h], d - 1)
        lower = tri(qc[:, h:], kc[:, h:], vc[:, h:], d - 1)
        cross = _attn_block_stats(qc[:, h:], kc[:, :h], vc[:, :h], None, scale)
        n2, m2, l2 = _merge_stats(lower, cross)
        return (jnp.concatenate([n1, n2], axis=1),
                jnp.concatenate([m1, m2], axis=1),
                jnp.concatenate([l1, l2], axis=1))

    num, _, l = tri(q, k, v, depth)
    return (num / l[..., None]).astype(q.dtype)


def bidirectional_attention(q, k, v) -> jax.Array:
    scale = 1.0 / math.sqrt(q.shape[-1])
    return _gqa_scores_softmax_out(q, k, v, None, scale)


def decode_attention(q, k_cache, v_cache, cache_len) -> jax.Array:
    """One-token attention against a cache. q:(B,1,Hq,D) cache:(B,Smax,Hkv,D).

    cache_len: (B,) valid lengths (positions >= cache_len are masked out).
    """
    B, Smax = k_cache.shape[0], k_cache.shape[1]
    mask = jnp.arange(Smax)[None, :] < cache_len[:, None]     # (B, Smax)
    scale = 1.0 / math.sqrt(q.shape[-1])
    return _gqa_scores_softmax_out(q, k_cache, v_cache, mask[:, None, None, None], scale)


# ------------------------------------------------------------------ MLP ----
def swiglu_mlp(x, wi_gate, wi_up, wo, shard):
    h = shard(jnp.einsum("bsd,df->bsf", x, wi_gate), "batch", "seq", "mlp")
    u = jnp.einsum("bsd,df->bsf", x, wi_up)
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, wo)


def gelu_mlp(x, wi, bi, wo, bo):
    h = jnp.einsum("bsd,df->bsf", x, wi) + bi
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, wo) + bo


# ------------------------------------------------------------------ MoE ----
def moe_block(x, p, cfg: ModelConfig, shard) -> Tuple[jax.Array, jax.Array]:
    """Sort-based top-k MoE with per-sequence capacity (Megablocks-lite).

    x: (B, S, D). Returns (out, aux_load_balance_loss).
    Memory is O(B*(E*C + S*K)*D); the (T x E x C) one-hot dispatch tensor of
    the classic MeshTF formulation is never materialized.

    Sharding discipline (see EXPERIMENTS.md §Perf, hillclimb #1): every
    dispatch intermediate is pinned to batch-only sharding. The gathers and
    scatters index along the *sequence* axis; if the residual stream enters
    sequence-sharded (seq_sp), GSPMD cannot partition them and falls back to
    full f32 rematerialization — an ~8 GB all-gather per op per layer at
    qwen3-moe scale. Pinning x to ("batch", None, None) makes the whole
    dispatch local to the batch shard; only the expert einsums communicate.
    """
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = max(1, math.ceil(K * S * cfg.capacity_factor / E))
    # un-shard the sequence locally: dispatch is batch-parallel
    x = shard(x, "batch", None, None)
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)                      # (B,S,K)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # aux load-balance loss (Switch-style)
    frac_tokens = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=2), axis=(0, 1)) / K
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)

    flat_e = shard(eidx.reshape(B, S * K), "batch", None)
    order = jnp.argsort(flat_e, axis=-1, stable=True)          # (B, S*K)
    order = shard(order, "batch", None)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # rank of each slot within its expert group
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_e)
    rank = jnp.arange(S * K)[None, :] - jnp.take_along_axis(starts, sorted_e, axis=-1)
    keep = rank < C
    slot = shard(jnp.where(keep, sorted_e * C + rank, E * C), "batch", None)

    tok = order // K                                            # source token
    xg = jnp.take_along_axis(x, tok[..., None], axis=1)         # (B, S*K, D)
    xg = shard(xg, "batch", None, None)

    def scatter_one(buf, slot_b, xg_b):
        return buf.at[slot_b].set(xg_b, mode="drop")

    buf = jax.vmap(scatter_one)(
        jnp.zeros((B, E * C + 1, D), x.dtype), slot, xg
    )[:, : E * C].reshape(B, E, C, D)
    buf = shard(buf, "batch", "expert", None, None)

    # expert SwiGLU
    h = jnp.einsum("becd,edf->becf", buf, p["wi_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["wi_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    yb = jnp.einsum("becf,efd->becd", h, p["wo"])
    yb = shard(yb, "batch", "expert", None, None)

    yb_flat = shard(yb.reshape(B, E * C, D), "batch", None, None)
    y_sorted = jax.vmap(lambda b, s: b.at[jnp.minimum(s, E * C - 1)].get())(yb_flat, slot)
    y_sorted = jnp.where(keep[..., None], y_sorted,
                         jnp.zeros((), x.dtype))                # stay bf16
    # unsort back to (B, S*K, D)
    inv = jnp.argsort(order, axis=-1)
    y_flat = jnp.take_along_axis(y_sorted, inv[..., None], axis=1)
    y_flat = shard(y_flat, "batch", None, None)
    y = (y_flat.reshape(B, S, K, D) * gates[..., None].astype(x.dtype)).sum(axis=2)
    return y, aux


# ---------------------------------------------------------- SSD (Mamba2) ---
def _segsum(log_a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} log_a[..., k], -inf for j>i."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]                  # i, j
    mask = jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Mamba-2 SSD (state-space dual) forward, chunked (ref for kernels/ssd).

    x:  (B, S, H, P)   values
    dt: (B, S, H)      post-softplus step sizes
    A:  (H,)           negative decay rates
    Bm: (B, S, N)      input projections (shared across heads)
    Cm: (B, S, N)      output projections
    Returns y: (B, S, H, P) and final state (B, H, P, N).
    """
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xr = x.reshape(Bb, nc, chunk, H, P)
    dtr = dt.reshape(Bb, nc, chunk, H)
    Br = Bm.reshape(Bb, nc, chunk, N)
    Cr = Cm.reshape(Bb, nc, chunk, N)

    log_a = (dtr * A[None, None, None, :]).astype(jnp.float32)   # (B,nc,Q,H) <= 0
    log_a = jnp.moveaxis(log_a, -1, 2)                           # (B,nc,H,Q)
    L = jnp.exp(_segsum(log_a))                                  # (B,nc,H,Q,Q)

    xdt = xr * dtr[..., None]                                    # (B,nc,Q,H,P)

    # intra-chunk (quadratic within chunk)
    cb = jnp.einsum("bcqn,bckn->bcqk", Cr, Br).astype(jnp.float32)
    y_intra = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", cb, L, xdt.astype(jnp.float32))

    # per-chunk outgoing state: sum_i decay(i->end) * dt_i x_i B_i
    decay_out = jnp.exp(jnp.cumsum(log_a[..., ::-1], axis=-1)[..., ::-1] - log_a)
    states = jnp.einsum("bchq,bcqn,bcqhp->bchpn", decay_out, Br.astype(jnp.float32),
                        xdt.astype(jnp.float32))

    # inter-chunk recurrence
    chunk_decay = jnp.exp(jnp.sum(log_a, axis=-1))               # (B,nc,H)

    def step(h, args):
        st, dec = args
        h_new = h * dec[..., None, None] + st
        return h_new, h                                          # emit state *before* chunk

    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    hT, h_prev = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                          # (B,nc,H,P,N)

    # inter-chunk contribution: C_t · decay(start->t) · h_prev
    decay_in = jnp.exp(jnp.cumsum(log_a, axis=-1))               # (B,nc,H,Q)
    y_inter = jnp.einsum("bcqn,bchq,bchpn->bcqhp", Cr.astype(jnp.float32),
                         decay_in, h_prev)

    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y.astype(x.dtype), hT


def ssd_decode_step(h, x, dt, A, Bm, Cm):
    """O(1) SSD decode. h:(B,H,P,N) x:(B,H,P) dt:(B,H) Bm/Cm:(B,N)."""
    da = jnp.exp((dt * A[None, :]).astype(jnp.float32))          # (B,H)
    contrib = jnp.einsum("bhp,bn->bhpn", (x * dt[..., None]).astype(jnp.float32),
                         Bm.astype(jnp.float32))
    h_new = h * da[..., None, None] + contrib
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cm.astype(jnp.float32))
    return y.astype(x.dtype), h_new
