"""Public model API: family dispatch + input specs for every (arch x shape).

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every model
input (weak-type-correct, shardable, zero allocation) — the dry-run contract.
``input_axes`` returns the matching logical-axes pytree for shardings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, lm
from repro.models.lm import Cache
from repro.models.params import (
    NULL_SHARDER,
    Sharder,
    init_params,
    param_axes,
    param_shapes,
    param_shardings,
)


def get_module(cfg: ModelConfig):
    return encdec if cfg.family == "encdec" else lm


def schema(cfg: ModelConfig):
    return get_module(cfg).schema(cfg)


def init(cfg: ModelConfig, rng: jax.Array):
    return init_params(schema(cfg), rng, jnp.dtype(cfg.param_dtype))


def specs(cfg: ModelConfig):
    """Param ShapeDtypeStructs — dry-run stand-in for real weights."""
    return param_shapes(schema(cfg), jnp.dtype(cfg.param_dtype))


def axes(cfg: ModelConfig):
    return param_axes(schema(cfg))


def shardings(cfg: ModelConfig, mesh):
    return param_shardings(schema(cfg), mesh, cfg.rules())


# ------------------------------------------------------------ input specs --
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs for one cell. For decode kinds this includes the cache."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = jnp.dtype(cfg.compute_dtype)
    tok = lambda *s: jax.ShapeDtypeStruct(s, i32)

    if cfg.family == "encdec":
        if shape.kind == "train":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), act),
                "tokens": tok(B, encdec.DEC_LEN),
                "labels": tok(B, encdec.DEC_LEN),
            }
        if shape.kind == "prefill":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), act),
                "tokens": tok(B, 1),
            }
        return {"tokens": tok(B, 1), "cache": encdec.cache_specs(cfg, B, S)}

    batch: Dict[str, Any] = {}
    if shape.kind == "train":
        batch["tokens"] = tok(B, S)
        batch["labels"] = tok(B, S)
    elif shape.kind == "prefill":
        batch["tokens"] = tok(B, S)
    else:  # decode: one new token against a cache of S
        batch["tokens"] = tok(B, 1)
        batch["cache"] = lm.cache_specs(cfg, B, S)

    if cfg.family == "vlm":
        if shape.kind in ("train", "prefill"):
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, min(cfg.num_patches, S), cfg.d_model), act)
            batch["positions"] = tok(3, B, S)
        else:
            batch["positions"] = tok(3, B, 1)
    return batch


def input_axes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    ax: Dict[str, Any] = {}
    if cfg.family == "encdec":
        if shape.kind == "train":
            return {"frames": ("batch", None, None),
                    "tokens": ("batch", None), "labels": ("batch", None)}
        if shape.kind == "prefill":
            return {"frames": ("batch", None, None), "tokens": ("batch", None)}
        return {"tokens": ("batch", None), "cache": _encdec_cache_axes()}

    if shape.kind == "train":
        ax = {"tokens": ("batch", None), "labels": ("batch", None)}
    elif shape.kind == "prefill":
        ax = {"tokens": ("batch", None)}
    else:
        ax = {"tokens": ("batch", None), "cache": lm.cache_axes(cfg)}
    if cfg.family == "vlm":
        if shape.kind in ("train", "prefill"):
            ax["patch_embeds"] = ("batch", None, None)
            ax["positions"] = (None, "batch", None)
        else:
            ax["positions"] = (None, "batch", None)
    return ax


def _encdec_cache_axes() -> Cache:
    attn = ("layers", "batch", "cache_seq", "kv_heads", None)
    return Cache(k=attn, v=attn, shared_k=attn, shared_v=attn, length=("batch",))


# ------------------------------------------------------------- smoke data --
def smoke_batch(cfg: ModelConfig, shape_kind: str, rng: jax.Array,
                batch: int = 2, seq: int = 64) -> Dict[str, Any]:
    """Small concrete batch for CPU smoke tests (matches input_specs layout)."""
    k1, k2 = jax.random.split(rng)
    act = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "encdec":
        dec = 16
        if shape_kind == "train":
            return {
                "frames": jax.random.normal(k1, (batch, seq, cfg.d_model), act),
                "tokens": jax.random.randint(k2, (batch, dec), 0, cfg.vocab_size),
                "labels": jax.random.randint(k2, (batch, dec), 0, cfg.vocab_size),
            }
        return {
            "frames": jax.random.normal(k1, (batch, seq, cfg.d_model), act),
            "tokens": jax.random.randint(k2, (batch, 1), 0, cfg.vocab_size),
        }
    out = {
        "tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size),
    }
    if shape_kind != "train":
        del out["labels"]
    if cfg.family == "vlm":
        np_ = min(cfg.num_patches, seq)
        out["patch_embeds"] = jax.random.normal(k1, (batch, np_, cfg.d_model), act)
        pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
        out["positions"] = jnp.stack([pos, pos, pos])
    return out
