"""Unified decoder-only LM covering dense / moe / ssm / hybrid / vlm families.

All families share one stacked-layer scan (`jax.lax.scan` over a leading
"layers" axis) so the lowered HLO stays small regardless of depth — the
production pattern for 80-layer+ models.

Public surface (all pure functions):
  schema(cfg)                                -> ParamSpec pytree
  forward_train(params, batch, cfg, shard)   -> (loss, metrics)
  prefill(params, batch, cfg, shard)         -> (last_logits, Cache)
  decode_step(params, batch, cache, cfg, shard) -> (logits, Cache)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.params import NULL_SHARDER, ParamSpec

Params = Dict[str, Any]

MOE_AUX_WEIGHT = 0.01


def _dtype(name: str):
    return jnp.dtype(name)


# ================================================================ schema ====
def _attn_schema(cfg: ModelConfig, stacked: bool, prefix_dims=()) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    lead = prefix_dims
    la = ("layers",) * len(prefix_dims)
    s: Params = {
        "wq": ParamSpec(lead + (d, cfg.num_heads * hd), la + ("embed_param", "qkv")),
        "wk": ParamSpec(lead + (d, cfg.num_kv_heads * hd), la + ("embed_param", "kv_heads")),
        "wv": ParamSpec(lead + (d, cfg.num_kv_heads * hd), la + ("embed_param", "kv_heads")),
        "wo": ParamSpec(lead + (cfg.num_heads * hd, d), la + ("qkv", "embed_param")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec(lead + (cfg.num_heads * hd,), la + ("qkv",), init="zeros")
        s["bk"] = ParamSpec(lead + (cfg.num_kv_heads * hd,), la + ("kv_heads",), init="zeros")
        s["bv"] = ParamSpec(lead + (cfg.num_kv_heads * hd,), la + ("kv_heads",), init="zeros")
    return s


def _ffn_schema(cfg: ModelConfig, prefix_dims=()) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    lead, la = prefix_dims, ("layers",) * len(prefix_dims)
    if cfg.family == "moe":
        e = cfg.num_experts
        return {
            "router": ParamSpec(lead + (d, e), la + ("embed_param", None)),
            "wi_gate": ParamSpec(lead + (e, d, f), la + ("expert", "embed_param", "mlp")),
            "wi_up": ParamSpec(lead + (e, d, f), la + ("expert", "embed_param", "mlp")),
            "wo": ParamSpec(lead + (e, f, d), la + ("expert", "mlp", "embed_param")),
        }
    if cfg.mlp_style == "mlp2":    # up/down only (granite/minitron style)
        return {
            "wi_up": ParamSpec(lead + (d, f), la + ("embed_param", "mlp")),
            "wo": ParamSpec(lead + (f, d), la + ("mlp", "embed_param")),
        }
    return {
        "wi_gate": ParamSpec(lead + (d, f), la + ("embed_param", "mlp")),
        "wi_up": ParamSpec(lead + (d, f), la + ("embed_param", "mlp")),
        "wo": ParamSpec(lead + (f, d), la + ("mlp", "embed_param")),
    }


def _ssd_schema(cfg: ModelConfig, prefix_dims=()) -> Params:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    h = di // cfg.ssm_head_dim
    lead, la = prefix_dims, ("layers",) * len(prefix_dims)
    return {
        "wz": ParamSpec(lead + (d, di), la + ("embed_param", "mlp")),
        "wx": ParamSpec(lead + (d, di), la + ("embed_param", "mlp")),
        "wB": ParamSpec(lead + (d, n), la + ("embed_param", "state")),
        "wC": ParamSpec(lead + (d, n), la + ("embed_param", "state")),
        "wdt": ParamSpec(lead + (d, h), la + ("embed_param", "heads")),
        "A_log": ParamSpec(lead + (h,), la + ("heads",), init="zeros"),
        "dt_bias": ParamSpec(lead + (h,), la + ("heads",), init="zeros"),
        "D_skip": ParamSpec(lead + (h,), la + ("heads",), init="ones"),
        "norm_w": ParamSpec(lead + (di,), la + ("mlp",), init="ones"),
        "out": ParamSpec(lead + (di, d), la + ("mlp", "embed_param")),
    }


def schema(cfg: ModelConfig) -> Params:
    """Parameter schema for decoder-only families (see encdec.py for whisper)."""
    d, nl = cfg.d_model, cfg.num_layers
    s: Params = {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed_param")),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((d, cfg.vocab_size), ("embed_param", "vocab"))
    s["final_norm"] = ParamSpec((d,), ("embed",), init="ones")

    lead = (nl,)
    if cfg.family in ("dense", "vlm", "moe"):
        s["blocks"] = {
            "ln1": ParamSpec(lead + (d,), ("layers", "embed"), init="ones"),
            "ln2": ParamSpec(lead + (d,), ("layers", "embed"), init="ones"),
            **_attn_schema(cfg, True, lead),
            "ffn": _ffn_schema(cfg, lead),
        }
    elif cfg.family == "ssm":
        s["blocks"] = {
            "ln1": ParamSpec(lead + (d,), ("layers", "embed"), init="ones"),
            **_ssd_schema(cfg, lead),
        }
    elif cfg.family == "hybrid":
        s["blocks"] = {
            "ln1": ParamSpec(lead + (d,), ("layers", "embed"), init="ones"),
            **_ssd_schema(cfg, lead),
        }
        s["shared_attn"] = {
            "ln1": ParamSpec((d,), ("embed",), init="ones"),
            "ln2": ParamSpec((d,), ("embed",), init="ones"),
            **_attn_schema(cfg, False),
            "ffn": {
                "wi_gate": ParamSpec((d, cfg.d_ff), ("embed_param", "mlp")),
                "wi_up": ParamSpec((d, cfg.d_ff), ("embed_param", "mlp")),
                "wo": ParamSpec((cfg.d_ff, d), ("mlp", "embed_param")),
            },
        }
    else:
        raise ValueError(cfg.family)
    return s


# ================================================================ caches ====
@dataclasses.dataclass
class Cache:
    """Decode-time state. Attention caches are (L, B, Smax, Hkv, hd)."""
    k: Optional[jax.Array] = None
    v: Optional[jax.Array] = None
    ssm: Optional[jax.Array] = None          # (L, B, H, P, N)
    shared_k: Optional[jax.Array] = None     # (napps, B, Smax, Hkv, hd)
    shared_v: Optional[jax.Array] = None
    length: Optional[jax.Array] = None       # (B,) valid entries

    def tree_flatten(self):
        fields = dataclasses.fields(self)
        return [getattr(self, f.name) for f in fields], None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(Cache, Cache.tree_flatten, Cache.tree_unflatten)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    """ShapeDtypeStructs for the decode cache (dry-run stand-ins)."""
    dt = _dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    c = Cache(length=jax.ShapeDtypeStruct((batch,), jnp.int32))
    if cfg.family in ("dense", "vlm", "moe"):
        shp = (cfg.num_layers, batch, max_len, cfg.effective_kv_heads, hd)
        c.k = jax.ShapeDtypeStruct(shp, dt)
        c.v = jax.ShapeDtypeStruct(shp, dt)
    if cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm_expand * cfg.d_model
        h = di // cfg.ssm_head_dim
        c.ssm = jax.ShapeDtypeStruct(
            (cfg.num_layers, batch, h, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    if cfg.family == "hybrid":
        napps = cfg.num_layers // cfg.attn_period
        shp = (napps, batch, max_len, cfg.num_kv_heads, hd)
        c.shared_k = jax.ShapeDtypeStruct(shp, dt)
        c.shared_v = jax.ShapeDtypeStruct(shp, dt)
    return c


def cache_axes(cfg: ModelConfig) -> Cache:
    """Logical axes matching cache_specs (for shardings)."""
    c = Cache(length=("batch",))
    attn_axes = ("layers", "batch", "cache_seq", "kv_heads", None)
    if cfg.family in ("dense", "vlm", "moe"):
        c.k = attn_axes
        c.v = attn_axes
    if cfg.family in ("ssm", "hybrid"):
        c.ssm = ("layers", "batch", "heads", None, "state")
    if cfg.family == "hybrid":
        c.shared_k = attn_axes
        c.shared_v = attn_axes
    return c


# ============================================================== forward =====
def _attention(x, p, cfg: ModelConfig, shard, positions, mode,
               kv_cache=None, cache_len=None):
    """Self-attention for one block. Returns (out, new_kv) where new_kv is
    (k, v) for prefill, updated (k_cache, v_cache) for decode, None for train.
    """
    B, S, D = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"])
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"])
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = shard(q.reshape(B, S, cfg.num_heads, hd), "batch", None, "heads", None)
    k = shard(k.reshape(B, S, cfg.num_kv_heads, hd), "batch", None, "kv_heads", None)
    v = shard(v.reshape(B, S, cfg.num_kv_heads, hd), "batch", None, "kv_heads", None)

    if cfg.mrope:
        q = L.apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = L.apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)

    if cfg.kv_head_replication > 1 and mode in ("prefill", "decode"):
        # duplicate kv heads so the cache shards over the model axis
        # (identical math: each q group maps to a copy of its kv head)
        r = cfg.kv_head_replication
        k = shard(jnp.repeat(k, r, axis=2), "batch", None, "kv_heads", None)
        v = shard(jnp.repeat(v, r, axis=2), "batch", None, "kv_heads", None)

    if mode in ("train", "prefill"):
        if cfg.attention_impl == "pallas":
            from repro.kernels import flash_attention
            out = flash_attention(q, k, v, causal=True)
        elif cfg.attention_impl == "tri":
            out = L.causal_attention_tri(q, k, v)
        else:
            out = L.causal_attention_ref(q, k, v)
        new_kv = (k, v) if mode == "prefill" else None
    else:  # decode: S == 1
        kc, vc = kv_cache
        pos = cache_len  # (B,)
        kc = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice_in_dim(c, kk, i, 0)
                      )(kc, k, pos)
        vc = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice_in_dim(c, vv, i, 0)
                      )(vc, v, pos)
        out = L.decode_attention(q, kc, vc, pos + 1)
        new_kv = (kc, vc)
    out = out.reshape(B, S, cfg.num_heads * hd)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"]), new_kv


def _ffn(x, p, cfg: ModelConfig, shard):
    """Returns (out, aux_loss)."""
    if cfg.family == "moe":
        return L.moe_block(x, p, cfg, shard)
    if cfg.mlp_style == "mlp2":
        h = shard(jnp.einsum("bsd,df->bsf", x, p["wi_up"]),
                  "batch", "seq", "mlp")
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        return jnp.einsum("bsf,fd->bsd", h, p["wo"]), jnp.float32(0)
    return L.swiglu_mlp(x, p["wi_gate"], p["wi_up"], p["wo"], shard), jnp.float32(0)


def _transformer_block(x, p, cfg, shard, positions, mode, kv_cache=None, cache_len=None):
    h, new_kv = _attention(
        L.rms_norm(x, p["ln1"], cfg.norm_eps), p, cfg, shard, positions, mode,
        kv_cache, cache_len)
    x = x + h
    x = shard(x, "batch", "seq_sp", "embed")
    h, aux = _ffn(L.rms_norm(x, p["ln2"], cfg.norm_eps), p.get("ffn", p), cfg, shard)
    x = x + h
    return shard(x, "batch", "seq_sp", "embed"), new_kv, aux


def _ssd_block(x, p, cfg: ModelConfig, shard, mode, ssm_state=None):
    """Mamba-2 block. Returns (out, new_state)."""
    B, S, D = x.shape
    di = cfg.ssm_expand * D
    nh = di // cfg.ssm_head_dim
    xin = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    z = jnp.einsum("bsd,de->bse", xin, p["wz"])
    xv = jnp.einsum("bsd,de->bse", xin, p["wx"])
    xv = shard(xv, "batch", None, "mlp")
    Bm = jnp.einsum("bsd,dn->bsn", xin, p["wB"])
    Cm = jnp.einsum("bsd,dn->bsn", xin, p["wC"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", xin, p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xv.reshape(B, S, nh, cfg.ssm_head_dim)

    if mode in ("train", "prefill"):
        if cfg.ssd_impl == "pallas" and mode == "train":
            from repro.kernels import ssd_scan
            y = ssd_scan(xh, dt, A, Bm, Cm, chunk=min(cfg.ssm_chunk, S))
            new_state = None
        else:
            y, h_final = L.ssd_chunked(xh, dt, A, Bm, Cm, min(cfg.ssm_chunk, S))
            new_state = h_final if mode == "prefill" else None
    else:
        y, new_state = L.ssd_decode_step(
            ssm_state, xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0])
        y = y[:, None]
    y = y + xh * p["D_skip"][None, None, :, None]
    y = y.reshape(B, S, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = L.rms_norm(y, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out"])
    return shard(x + out, "batch", "seq_sp", "embed"), new_state


def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def _embed(params, batch, cfg: ModelConfig, shard):
    """Token (+patch for vlm) embedding. Returns (x, positions)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg.compute_dtype))
    if cfg.family == "vlm" and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
    x = shard(x, "batch", "seq_sp", "embed")
    if cfg.mrope:
        positions = batch["positions"]        # (3, B, S)
    else:
        positions = batch.get("positions")
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return x, positions


def _unembed(x, params, cfg: ModelConfig, shard):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    return shard(logits, "batch", None, "vocab")


def _run_layers(x, params, cfg: ModelConfig, shard, positions, mode,
                cache: Optional[Cache] = None):
    """Scan over stacked layers; handles every decoder-only family."""
    blocks = params["blocks"]
    nl = cfg.num_layers
    aux_total = jnp.float32(0)
    new_cache = Cache(length=None) if cache is None else Cache(length=cache.length)

    if cfg.family in ("dense", "vlm", "moe"):
        def body(carry, inp):
            xc = carry
            bp, kvc = inp
            kv = None if kvc is None else (kvc[0], kvc[1])
            xc, new_kv, aux = _transformer_block(
                xc, bp, cfg, shard, positions, mode, kv,
                cache.length if cache else None)
            out = (jnp.stack(new_kv), aux) if new_kv is not None else (0, aux)
            return xc, out

        body = _remat(body, cfg)
        kv_in = None
        if mode == "decode":
            kv_in = jnp.stack([cache.k, cache.v], axis=1)   # (L, 2, B, S, K, hd)
        elif mode == "prefill":
            kv_in = None
        if cfg.scan_layers:
            if kv_in is None:
                x, (kv_out, auxs) = jax.lax.scan(
                    lambda c, bp: body(c, (bp, None)), x, blocks)
            else:
                x, (kv_out, auxs) = jax.lax.scan(body, x, (blocks, kv_in))
            aux_total = jnp.sum(auxs)
            if mode == "prefill":
                new_cache.k, new_cache.v = kv_out[:, 0], kv_out[:, 1]
            elif mode == "decode":
                new_cache.k, new_cache.v = kv_out[:, 0], kv_out[:, 1]
        else:
            ks, vs = [], []
            for i in range(nl):
                bp = jax.tree.map(lambda a: a[i], blocks)
                kvc = None if kv_in is None else kv_in[i]
                x, out = body(x, (bp, kvc))
                if mode in ("prefill", "decode"):
                    ks.append(out[0][0]); vs.append(out[0][1])
                aux_total = aux_total + out[1]
            if ks:
                new_cache.k, new_cache.v = jnp.stack(ks), jnp.stack(vs)
        return x, new_cache, aux_total

    if cfg.family == "ssm":
        def body(carry, inp):
            xc = carry
            bp, st = inp
            xc, new_st = _ssd_block(xc, bp, cfg, shard, mode, st)
            return xc, (new_st if new_st is not None else 0)

        body = _remat(body, cfg)
        st_in = cache.ssm if (cache is not None and mode == "decode") else None
        if cfg.scan_layers:
            if st_in is not None:
                x, st_out = jax.lax.scan(body, x, (blocks, st_in))
            else:
                x, st_out = jax.lax.scan(lambda c, bp: body(c, (bp, None)),
                                         x, blocks)
        else:  # unrolled (calibration probes / small models)
            sts = []
            for i in range(nl):
                bp = jax.tree.map(lambda a: a[i], blocks)
                st = None if st_in is None else st_in[i]
                x, st_o = body(x, (bp, st))
                sts.append(st_o)
            st_out = jnp.stack(sts) if mode in ("prefill", "decode") else 0
        if mode in ("prefill", "decode"):
            new_cache.ssm = st_out
        return x, new_cache, aux_total

    if cfg.family == "hybrid":
        period = cfg.attn_period
        napps = nl // period
        shared = params["shared_attn"]

        # carry = (x, shared_k, shared_v); scanned = (blocks, ssm_state, idx)
        def body(carry, inp):
            xc, sk, sv = carry
            bp, st, idx = inp
            xc, new_st = _ssd_block(xc, bp, cfg, shard, mode, st)

            def with_attn(args):
                xc, sk, sv = args
                app = idx // period
                if mode == "decode":
                    kvc = (jax.lax.dynamic_index_in_dim(sk, app, 0, keepdims=False),
                           jax.lax.dynamic_index_in_dim(sv, app, 0, keepdims=False))
                    xa, nkv, _ = _transformer_block(
                        xc, shared, cfg, shard, positions, mode, kvc, cache.length)
                    sk = jax.lax.dynamic_update_index_in_dim(sk, nkv[0], app, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(sv, nkv[1], app, 0)
                else:
                    xa, nkv, _ = _transformer_block(
                        xc, shared, cfg, shard, positions, mode, None, None)
                    if mode == "prefill":
                        sk = jax.lax.dynamic_update_index_in_dim(sk, nkv[0], app, 0)
                        sv = jax.lax.dynamic_update_index_in_dim(sv, nkv[1], app, 0)
                return xa, sk, sv

            is_attn = (idx % period) == (period - 1)
            xc, sk, sv = jax.lax.cond(is_attn, with_attn, lambda a: a, (xc, sk, sv))
            return (xc, sk, sv), (new_st if new_st is not None else 0)

        body = _remat(body, cfg)
        hd = cfg.resolved_head_dim
        B = x.shape[0]
        if mode == "decode":
            sk, sv = cache.shared_k, cache.shared_v
            st_in = cache.ssm
        elif mode == "prefill":
            Smax = x.shape[1]
            sk = jnp.zeros((napps, B, Smax, cfg.num_kv_heads, hd), x.dtype)
            sv = jnp.zeros((napps, B, Smax, cfg.num_kv_heads, hd), x.dtype)
            st_in = None
        else:  # train: with_attn never touches sk/sv -> zero-size dummies
            sk = sv = jnp.zeros((0,), x.dtype)
            st_in = None
        idxs = jnp.arange(nl)
        if cfg.scan_layers:
            if st_in is not None:
                (x, sk, sv), st_out = jax.lax.scan(
                    body, (x, sk, sv), (blocks, st_in, idxs))
            else:
                (x, sk, sv), st_out = jax.lax.scan(
                    lambda c, i: body(c, (i[0], None, i[1])),
                    (x, sk, sv), (blocks, idxs))
        else:  # unrolled (calibration probes / small models)
            sts = []
            carry = (x, sk, sv)
            for i in range(nl):
                bp = jax.tree.map(lambda a: a[i], blocks)
                st = None if st_in is None else st_in[i]
                carry, st_o = body(carry, (bp, st, idxs[i]))
                sts.append(st_o)
            x, sk, sv = carry
            st_out = jnp.stack(sts) if mode in ("prefill", "decode") else 0
        if mode in ("prefill", "decode"):
            new_cache.ssm = st_out
            new_cache.shared_k, new_cache.shared_v = sk, sv
        return x, new_cache, aux_total

    raise ValueError(cfg.family)


# ================================================================= entry ====
def forward_train(params, batch, cfg: ModelConfig, shard=NULL_SHARDER):
    """Next-token CE loss. batch: tokens (B,S) int32, labels (B,S) int32
    (-1 = masked), plus family extras (patch_embeds / positions)."""
    x, positions = _embed(params, batch, cfg, shard)
    x, _, aux = _run_layers(x, params, cfg, shard, positions, "train")
    logits = _unembed(x, params, cfg, shard).astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = (lse - picked) * mask
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + MOE_AUX_WEIGHT * aux
    return total, {"loss": loss, "aux_loss": aux}


def prefill(params, batch, cfg: ModelConfig, shard=NULL_SHARDER):
    """Process a full prompt; returns (last_token_logits, Cache)."""
    x, positions = _embed(params, batch, cfg, shard)
    B, S = batch["tokens"].shape
    cache = Cache(length=jnp.full((B,), S, jnp.int32))
    x, new_cache, _ = _run_layers(x, params, cfg, shard, positions, "prefill",
                                  cache)
    new_cache.length = cache.length
    logits = _unembed(x[:, -1:], params, cfg, shard)
    return logits[:, 0], new_cache


def decode_step(params, batch, cache: Cache, cfg: ModelConfig, shard=NULL_SHARDER):
    """One decode step. batch: tokens (B,1). Returns (logits (B,V), Cache)."""
    x, positions = _embed(params, batch, cfg, shard)
    if not cfg.mrope and batch.get("positions") is None:
        positions = cache.length[:, None]
    x, new_cache, _ = _run_layers(x, params, cfg, shard, positions, "decode", cache)
    new_cache.length = cache.length + 1
    logits = _unembed(x, params, cfg, shard)
    return logits[:, 0], new_cache
