"""Parameter schema: single source of truth for shapes, logical axes, and init.

A schema is a pytree (nested dicts) of ``ParamSpec``. From it we derive:
  * ``init_params``   — materialized arrays (tests, real training),
  * ``param_shapes``  — ShapeDtypeStructs (dry-run: no allocation),
  * ``param_axes``    — logical-axes pytree (sharding rule application),
  * ``param_shardings`` — NamedShardings for a mesh + rule table.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | scaled
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(schema, rng: jax.Array, dtype) -> Any:
    """Materialize a schema into actual arrays (fan-in scaled normal init)."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=_is_spec)
    rngs = jax.random.split(rng, len(leaves))
    out = []
    for spec, k in zip(leaves, rngs):
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, dtype))
        else:
            fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[-1], 1)
            std = spec.scale / math.sqrt(fan_in)
            out.append((jax.random.normal(k, spec.shape, jnp.float32) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def param_shapes(schema, dtype) -> Any:
    """ShapeDtypeStruct pytree — dry-run stand-in, no device allocation."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), schema, is_leaf=_is_spec
    )


def param_axes(schema) -> Any:
    return jax.tree.map(lambda s: s.axes, schema, is_leaf=_is_spec)


def logical_to_spec(axes: Sequence[Optional[str]], rules: Mapping[str, Any]) -> P:
    """Map logical axis names to a PartitionSpec via the rule table.

    Duplicate mesh axes (illegal in a PartitionSpec) keep the first occurrence;
    later dims fall back to replication.
    """
    used: set = set()
    out = []
    for name in axes:
        mesh_axes = rules.get(name) if name else None
        if mesh_axes is None:
            out.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        picked = tuple(a for a in mesh_axes if a not in used)
        used.update(picked)
        if not picked:
            out.append(None)
        elif len(picked) == 1:
            out.append(picked[0])
        else:
            out.append(picked)
    return P(*out)


def filter_rules_for_mesh(rules: Mapping[str, Any], mesh: Mesh) -> dict:
    """Drop mesh axes that don't exist on this mesh (e.g. 'pod' on single-pod)."""
    ok = set(mesh.axis_names)

    def fix(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in ok else None
        if not isinstance(v, (tuple, list)):
            return v        # non-axis option (e.g. pad_kv_cache flag)
        kept = tuple(a for a in v if a in ok)
        return kept if kept else None

    return {k: fix(v) for k, v in rules.items()}


def param_shardings(schema, mesh: Mesh, rules: Mapping[str, Any]) -> Any:
    rules = filter_rules_for_mesh(rules, mesh)

    def one(spec: ParamSpec):
        pspec = logical_to_spec(spec.axes, rules)
        # Refuse shardings that don't divide the dim: fall back to replication
        # for that dim (keeps whisper's 12 heads off the 16-way axis, etc.).
        fixed = []
        for dim, axis in zip(spec.shape, pspec):
            if axis is None:
                fixed.append(None)
                continue
            names = (axis,) if isinstance(axis, str) else axis
            total = 1
            for n in names:
                total *= mesh.shape[n]
            fixed.append(axis if dim % total == 0 else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree.map(one, schema, is_leaf=_is_spec)


class Sharder:
    """Applies with_sharding_constraint from logical axes; no-op without mesh."""

    def __init__(self, mesh: Optional[Mesh], rules: Mapping[str, Any]):
        self.mesh = mesh
        self.rules = filter_rules_for_mesh(rules, mesh) if mesh is not None else dict(rules)

    def __call__(self, x: jax.Array, *axes: Optional[str]) -> jax.Array:
        if self.mesh is None:
            return x
        assert len(axes) == x.ndim, (axes, x.shape)
        pspec = logical_to_spec(axes, self.rules)
        fixed = []
        for dim, axis in zip(x.shape, pspec):
            if axis is None:
                fixed.append(None)
                continue
            names = (axis,) if isinstance(axis, str) else axis
            total = 1
            for n in names:
                total *= self.mesh.shape[n]
            fixed.append(axis if dim % total == 0 else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*fixed))
        )


NULL_SHARDER = Sharder(None, {})
