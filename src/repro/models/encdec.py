"""Whisper-style encoder-decoder backbone.

The conv audio frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (B, S_enc, d_model). Decoder length is capped at
DEC_LEN (whisper's 448 max target positions).

Cache reuse: lm.Cache.k/v hold the decoder SELF-attention cache,
lm.Cache.shared_k/shared_v hold the CROSS-attention cache (encoder k/v).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.lm import Cache, _dtype, _remat
from repro.models.params import NULL_SHARDER, ParamSpec

DEC_LEN = 448

Params = Dict[str, Any]


def _attn_schema(cfg: ModelConfig, lead) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    la = ("layers",) * len(lead)
    return {
        "wq": ParamSpec(lead + (d, cfg.num_heads * hd), la + ("embed_param", "qkv")),
        "wk": ParamSpec(lead + (d, cfg.num_kv_heads * hd), la + ("embed_param", "kv_heads")),
        "wv": ParamSpec(lead + (d, cfg.num_kv_heads * hd), la + ("embed_param", "kv_heads")),
        "wo": ParamSpec(lead + (cfg.num_heads * hd, d), la + ("qkv", "embed_param")),
        "bq": ParamSpec(lead + (cfg.num_heads * hd,), la + ("qkv",), init="zeros"),
        "bv": ParamSpec(lead + (cfg.num_kv_heads * hd,), la + ("kv_heads",), init="zeros"),
    }


def _ln_schema(cfg, lead, name) -> Params:
    la = ("layers",) * len(lead)
    return {
        f"{name}_w": ParamSpec(lead + (cfg.d_model,), la + ("embed",), init="ones"),
        f"{name}_b": ParamSpec(lead + (cfg.d_model,), la + ("embed",), init="zeros"),
    }


def _mlp_schema(cfg, lead) -> Params:
    la = ("layers",) * len(lead)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi": ParamSpec(lead + (d, f), la + ("embed_param", "mlp")),
        "bi": ParamSpec(lead + (f,), la + ("mlp",), init="zeros"),
        "wo": ParamSpec(lead + (f, d), la + ("mlp", "embed_param")),
        "bo": ParamSpec(lead + (d,), la + ("embed",), init="zeros"),
    }


def schema(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    enc_lead, dec_lead = (cfg.encoder_layers,), (cfg.num_layers,)
    return {
        "embed": ParamSpec((cfg.vocab_size, d), ("vocab", "embed_param")),
        "pos_embed": ParamSpec((DEC_LEN, d), (None, "embed_param")),
        "enc_blocks": {
            **_ln_schema(cfg, enc_lead, "ln1"),
            **_ln_schema(cfg, enc_lead, "ln2"),
            "attn": _attn_schema(cfg, enc_lead),
            "mlp": _mlp_schema(cfg, enc_lead),
        },
        "enc_final": {**_ln_schema(cfg, (), "ln")},
        "dec_blocks": {
            **_ln_schema(cfg, dec_lead, "ln1"),
            **_ln_schema(cfg, dec_lead, "ln2"),
            **_ln_schema(cfg, dec_lead, "ln3"),
            "self_attn": _attn_schema(cfg, dec_lead),
            "cross_attn": _attn_schema(cfg, dec_lead),
            "mlp": _mlp_schema(cfg, dec_lead),
        },
        "dec_final": {**_ln_schema(cfg, (), "ln")},
    }


def _sinusoid(S: int, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / (half - 1))
    t = jnp.arange(S)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=-1)


def _qkv(x, p, cfg, shard, kv_from=None):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    src = x if kv_from is None else kv_from
    q = (jnp.einsum("bsd,dq->bsq", x, p["wq"]) + p["bq"]).reshape(
        B, S, cfg.num_heads, hd)
    k = jnp.einsum("bsd,dq->bsq", src, p["wk"]).reshape(
        B, src.shape[1], cfg.num_kv_heads, hd)
    v = (jnp.einsum("bsd,dq->bsq", src, p["wv"]) + p["bv"]).reshape(
        B, src.shape[1], cfg.num_kv_heads, hd)
    return q, k, v


def _proj_out(out, p, cfg):
    B, S = out.shape[:2]
    return jnp.einsum("bsq,qd->bsd", out.reshape(B, S, -1), p["wo"])


def _encoder(params, frames, cfg: ModelConfig, shard):
    x = frames.astype(_dtype(cfg.compute_dtype))
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)
    x = shard(x, "batch", "seq_sp", "embed")

    def body(xc, bp):
        h = L.layer_norm(xc, bp["ln1_w"], bp["ln1_b"], cfg.norm_eps)
        q, k, v = _qkv(h, bp["attn"], cfg, shard)
        xc = xc + _proj_out(L.bidirectional_attention(q, k, v), bp["attn"], cfg)
        h = L.layer_norm(xc, bp["ln2_w"], bp["ln2_b"], cfg.norm_eps)
        xc = xc + L.gelu_mlp(h, bp["mlp"]["wi"], bp["mlp"]["bi"],
                             bp["mlp"]["wo"], bp["mlp"]["bo"])
        return shard(xc, "batch", "seq_sp", "embed"), None

    body = _remat(body, cfg)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:  # unrolled (calibration probes)
        for i in range(cfg.encoder_layers):
            bp = jax.tree.map(lambda a: a[i], params["enc_blocks"])
            x, _ = body(x, bp)
    return L.layer_norm(x, params["enc_final"]["ln_w"], params["enc_final"]["ln_b"],
                        cfg.norm_eps)


def _cross_kv(params, enc_out, cfg, shard):
    """Precompute per-decoder-layer cross-attention K/V (stacked on layers)."""
    def one(bp):
        hd = cfg.resolved_head_dim
        B, S, _ = enc_out.shape
        k = jnp.einsum("bsd,dq->bsq", enc_out, bp["cross_attn"]["wk"]).reshape(
            B, S, cfg.num_kv_heads, hd)
        v = (jnp.einsum("bsd,dq->bsq", enc_out, bp["cross_attn"]["wv"])
             + bp["cross_attn"]["bv"]).reshape(B, S, cfg.num_kv_heads, hd)
        return k, v

    if cfg.scan_layers:
        return jax.lax.map(one, params["dec_blocks"])
    outs = [one(jax.tree.map(lambda a: a[i], params["dec_blocks"]))
            for i in range(cfg.num_layers)]
    return (jnp.stack([k for k, _ in outs]), jnp.stack([v for _, v in outs]))


def _decoder(params, tokens, cfg, shard, mode, cross_kv, cache: Cache = None):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg.compute_dtype))
    if mode == "decode":
        pos = cache.length
        pe = jax.vmap(lambda i: jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], i, 1, 0))(jnp.minimum(pos, DEC_LEN - 1))
        x = x + pe.astype(x.dtype)
    else:
        x = x + params["pos_embed"][:S].astype(x.dtype)
    x = shard(x, "batch", None, "embed")

    def body(carry, inp):
        xc = carry
        bp, ckv, kvc = inp
        h = L.layer_norm(xc, bp["ln1_w"], bp["ln1_b"], cfg.norm_eps)
        q, k, v = _qkv(h, bp["self_attn"], cfg, shard)
        if mode == "decode":
            kc, vc = kvc[0], kvc[1]
            pos = jnp.minimum(cache.length, kc.shape[1] - 1)
            kc = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice_in_dim(
                c, kk, i, 0))(kc, k, pos)
            vc = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice_in_dim(
                c, vv, i, 0))(vc, v, pos)
            att = L.decode_attention(q, kc, vc, pos + 1)
            new_kv = jnp.stack([kc, vc])
        else:
            att = L.causal_attention_ref(q, k, v, chunk_q=min(512, S))
            new_kv = jnp.stack([k, v]) if mode == "prefill" else 0
        xc = xc + _proj_out(att, bp["self_attn"], cfg)
        # cross attention
        h = L.layer_norm(xc, bp["ln2_w"], bp["ln2_b"], cfg.norm_eps)
        hd = cfg.resolved_head_dim
        qx = (jnp.einsum("bsd,dq->bsq", h, bp["cross_attn"]["wq"])
              + bp["cross_attn"]["bq"]).reshape(B, S, cfg.num_heads, hd)
        ck, cv = ckv
        att = L.bidirectional_attention(qx, ck, cv)
        xc = xc + _proj_out(att, bp["cross_attn"], cfg)
        h = L.layer_norm(xc, bp["ln3_w"], bp["ln3_b"], cfg.norm_eps)
        xc = xc + L.gelu_mlp(h, bp["mlp"]["wi"], bp["mlp"]["bi"],
                             bp["mlp"]["wo"], bp["mlp"]["bo"])
        return shard(xc, "batch", None, "embed"), new_kv

    body = _remat(body, cfg)
    kv_in = (jnp.stack([cache.k, cache.v], axis=1) if mode == "decode"
             else None)
    if cfg.scan_layers:
        if kv_in is not None:
            x, kv_out = jax.lax.scan(
                body, x, (params["dec_blocks"], cross_kv, kv_in))
        else:
            x, kv_out = jax.lax.scan(
                lambda c, i: body(c, (i[0], i[1], None)), x,
                (params["dec_blocks"], cross_kv))
    else:  # unrolled (calibration probes)
        kvs = []
        for i in range(cfg.num_layers):
            bp = jax.tree.map(lambda a: a[i], params["dec_blocks"])
            ckv = jax.tree.map(lambda a: a[i], cross_kv)
            kvc = None if kv_in is None else kv_in[i]
            x, kv = body(x, (bp, ckv, kvc))
            kvs.append(kv)
        kv_out = jnp.stack(kvs) if mode in ("prefill", "decode") else 0
    x = L.layer_norm(x, params["dec_final"]["ln_w"], params["dec_final"]["ln_b"],
                     cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].T)
    return shard(logits, "batch", None, "vocab"), kv_out


def forward_train(params, batch, cfg: ModelConfig, shard=NULL_SHARDER):
    """batch: frames (B,S_enc,D), tokens (B,DEC), labels (B,DEC)."""
    enc_out = _encoder(params, batch["frames"], cfg, shard)
    cross_kv = _cross_kv(params, enc_out, cfg, shard)
    logits, _ = _decoder(params, batch["tokens"], cfg, shard, "train", cross_kv)
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    loss = jnp.sum((lse - picked) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "aux_loss": jnp.float32(0)}


def prefill(params, batch, cfg: ModelConfig, shard=NULL_SHARDER):
    """Encode frames, precompute cross K/V, decode the BOS prompt (B,1)."""
    enc_out = _encoder(params, batch["frames"], cfg, shard)
    cross_kv = _cross_kv(params, enc_out, cfg, shard)
    B = batch["tokens"].shape[0]
    dt = _dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    cache = Cache(
        k=jnp.zeros((cfg.num_layers, B, DEC_LEN, cfg.num_kv_heads, hd), dt),
        v=jnp.zeros((cfg.num_layers, B, DEC_LEN, cfg.num_kv_heads, hd), dt),
        shared_k=cross_kv[0], shared_v=cross_kv[1],
        length=jnp.zeros((B,), jnp.int32),
    )
    logits, cache = _decode_one(params, batch["tokens"], cfg, shard, cache)
    return logits, cache


def _decode_one(params, tokens, cfg, shard, cache: Cache):
    cross_kv = (cache.shared_k, cache.shared_v)
    logits, kv_out = _decoder(params, tokens, cfg, shard, "decode", cross_kv, cache)
    new_cache = Cache(k=kv_out[:, 0], v=kv_out[:, 1],
                      shared_k=cache.shared_k, shared_v=cache.shared_v,
                      length=cache.length + 1)
    return logits[:, 0], new_cache


def decode_step(params, batch, cache: Cache, cfg: ModelConfig, shard=NULL_SHARDER):
    return _decode_one(params, batch["tokens"], cfg, shard, cache)


def cache_specs(cfg: ModelConfig, batch: int, enc_len: int) -> Cache:
    dt = _dtype(cfg.compute_dtype)
    hd = cfg.resolved_head_dim
    return Cache(
        k=jax.ShapeDtypeStruct((cfg.num_layers, batch, DEC_LEN, cfg.num_kv_heads, hd), dt),
        v=jax.ShapeDtypeStruct((cfg.num_layers, batch, DEC_LEN, cfg.num_kv_heads, hd), dt),
        shared_k=jax.ShapeDtypeStruct(
            (cfg.num_layers, batch, enc_len, cfg.num_kv_heads, hd), dt),
        shared_v=jax.ShapeDtypeStruct(
            (cfg.num_layers, batch, enc_len, cfg.num_kv_heads, hd), dt),
        length=jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
