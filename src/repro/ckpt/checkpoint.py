"""Fault-tolerant checkpointing: async, atomic, mesh-reshape restore.

Layout (one directory per step):
    <root>/step_000123.tmp/...      during write
    <root>/step_000123/             after atomic rename
        manifest.json               step, config hash, mesh shape, tree def
        arrays.npz                  flattened leaves (gathered host view)

Crash-only design: a checkpoint either fully exists (rename is atomic on a
POSIX filesystem) or is garbage-collected at next startup; the train driver
restores from the newest complete step.

Async: `save()` snapshots the state to host numpy (device_get is the only
synchronous part), then a daemon thread serializes in the background while
training continues. `wait()` (or context exit) drains pending writes —
called before the process exits or at a shutdown signal.

Elastic restore: arrays are stored as full (unsharded) host views, so
``restore(..., shardings=...)`` can re-lay them out on ANY mesh — restart on
fewer/more pods after a failure reshards transparently. At the scale where
full host views stop fitting, the layout swaps to shard-per-host files with
the same manifest contract (documented in DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _tree_paths(tree: Any):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None
        self._gc_incomplete()

    # ------------------------------------------------------------- naming --
    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:09d}")

    def _gc_incomplete(self) -> None:
        for name in os.listdir(self.root):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.root, name),
                              ignore_errors=True)

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.root, name, "manifest.json")):
                    steps.append(int(name[5:]))
        return max(steps) if steps else None

    # --------------------------------------------------------------- save --
    def save(self, step: int, state: Any, *, config_hash: str = "",
             mesh_shape: Optional[Dict[str, int]] = None,
             blocking: bool = False) -> None:
        """Snapshot to host then serialize asynchronously."""
        flat, treedef = _tree_paths(state)
        host = [np.asarray(jax.device_get(x)) for x in flat]
        manifest = {
            "step": step,
            "config_hash": config_hash,
            "mesh_shape": mesh_shape or {},
            "num_leaves": len(host),
            "treedef": str(treedef),
            "dtypes": [str(a.dtype) for a in host],
            "shapes": [list(a.shape) for a in host],
        }

        def _write():
            tmp = self._dir(step) + ".tmp"
            final = self._dir(step)
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{f"leaf_{i}": a for i, a in enumerate(host)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic commit
            self._retain()

        self.wait()                        # at most one in-flight write
        t = threading.Thread(target=_write, daemon=True)
        with self._lock:
            self._pending = t
        t.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        with self._lock:
            t = self._pending
        if t is not None:
            t.join()
            with self._lock:
                self._pending = None

    def _retain(self) -> None:
        steps = sorted(
            int(n[5:]) for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    # ------------------------------------------------------------- restore --
    def restore(self, like: Any, *, step: Optional[int] = None,
                shardings: Optional[Any] = None,
                expect_config_hash: str = "") -> Tuple[Any, int]:
        """Load into the structure of ``like``; optionally re-shard.

        ``shardings``: matching pytree of NamedShardings for the CURRENT
        mesh (which may differ from the writer's — elastic restore).
        Returns (state, step).
        """
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self._dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if expect_config_hash and manifest["config_hash"]:
            assert manifest["config_hash"] == expect_config_hash, \
                "checkpoint/config mismatch"
        flat_like, treedef = _tree_paths(like)
        npz = np.load(os.path.join(d, "arrays.npz"))
        assert manifest["num_leaves"] == len(flat_like), \
            (manifest["num_leaves"], len(flat_like))
        host = [npz[f"leaf_{i}"] for i in range(len(flat_like))]
        if shardings is not None:
            flat_sh = jax.tree.leaves(shardings)
            arrs = [jax.device_put(a, s) for a, s in zip(host, flat_sh)]
        else:
            arrs = [jax.numpy.asarray(a) for a in host]
        return jax.tree.unflatten(treedef, arrs), step

    @staticmethod
    def config_hash(obj: Any) -> str:
        blob = json.dumps(dataclasses.asdict(obj) if dataclasses.is_dataclass(obj)
                          else obj, sort_keys=True, default=str)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]
