"""int8 gradient compression with error feedback for the cross-pod axis.

Cross-pod links are the lowest-bandwidth hop of a multi-pod job (DCN, not
ICI), so the per-step gradient all-reduce on the ``pod`` axis is the natural
compression target: bf16 -> int8 quartered payload, with an error-feedback
residual so compression noise doesn't accumulate into the optimizer.

Used inside shard_map (see launch/train.py --compress-pod-grads).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum(grad: jax.Array, residual: jax.Array, axis_name: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback compressed all-reduce over ``axis_name``.

    Returns (mean-reduced gradient, new residual). Must run inside shard_map
    with ``axis_name`` bound.
    """
    g = grad.astype(jnp.float32) + residual
    q, scale = compress_int8(g)
    sent = decompress_int8(q, scale)
    new_residual = g - sent
    # int8 payload on the wire; reduction accumulates in f32.
    summed = jax.lax.psum(sent, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (summed / n).astype(grad.dtype), new_residual


def compress_tree(grads: Any, residuals: Any, axis_name: str) -> Tuple[Any, Any]:
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    outs = [compressed_psum(g, r, axis_name) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, new_r
