"""From-scratch sharded AdamW (+ cosine schedule, global-norm clipping).

Optimizer state is a pytree mirroring params (fp32 m/v regardless of param
dtype) so `param_shardings` apply verbatim — every state shard lives with its
parameter shard (ZeRO-style when params are FSDP-sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    params: Any, grads: Any, state: dict, cfg: AdamWConfig
) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = cosine_schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        step = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
