from repro.roofline.analysis import (
    HW,
    Hardware,
    KernelRoofline,
    RooflineReport,
    collective_bytes,
    host_copy_bandwidth,
    kernel_roofline,
    parse_hlo_collectives,
    roofline_terms,
)
from repro.roofline.model_flops import model_flops

__all__ = [
    "HW",
    "Hardware",
    "KernelRoofline",
    "RooflineReport",
    "collective_bytes",
    "host_copy_bandwidth",
    "kernel_roofline",
    "parse_hlo_collectives",
    "roofline_terms",
    "model_flops",
]
