from repro.roofline.analysis import (
    HW,
    Hardware,
    RooflineReport,
    collective_bytes,
    parse_hlo_collectives,
    roofline_terms,
)
from repro.roofline.model_flops import model_flops

__all__ = [
    "HW",
    "Hardware",
    "RooflineReport",
    "collective_bytes",
    "parse_hlo_collectives",
    "roofline_terms",
    "model_flops",
]
