"""Three-term roofline analysis from a compiled dry-run artifact.

This container has no TPU, so instead of wall-clock MFU we derive, per
(arch x shape x mesh):

  compute term    = HLO_FLOPs        / (chips * peak_FLOPs)
  memory term     = HLO_bytes        / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
bytes are NOT in cost_analysis: we parse the optimized HLO text and sum the
shaped payload of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.

Note on totals: XLA's cost_analysis on an SPMD-partitioned module reports
the *per-partition* program, so terms divide by per-chip peaks directly;
``normalize="global"`` multiplies by chip count first when an unpartitioned
(single-device-program) module is analyzed. The dry-run driver verifies
which convention holds by comparing against the analytic 6ND model and
records the ratio (MODEL_FLOPS / HLO_FLOPs) in every report row.

Hardware model (TPU v5e): 197 TFLOP/s bf16; 819 GB/s HBM; ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, Optional, Tuple

__all__ = ["HW", "Hardware", "collective_bytes", "roofline_terms",
           "RooflineReport", "parse_hlo_collectives", "KernelRoofline",
           "kernel_roofline", "host_copy_bandwidth"]


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    link_bw: float = 50e9             # bytes/s per ICI link
    hbm_per_chip: float = 16e9        # capacity (fit check)


HW = Hardware()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

# e.g. "bf16[16,4096,1024]{2,1,0}" or "f32[]"; tuple shapes handled by findall
_SHAPE_RE = re.compile(r"\b([a-z]\w*?)\[([\d,]*)\]")
_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")
# "%all-gather.7 = bf16[...] all-gather(" — capture result shapes + kind
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[a-z][^=]*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
# replica_groups={{0,1,..},{..}} or iota form replica_groups=[8,32]<=[256]...
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shapes(shape_str: str):
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dtype])
    return out


def _group_size(line: str) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))   # [num_groups, group_size]
    return 2  # unknown: conservative minimum


def _wire_bytes(kind: str, shapes, n: int) -> float:
    """Per-chip ICI wire traffic for a ring implementation of the op.

    R = result bytes (for -start tuples the result is the last/largest
    component). all-gather: (n-1)/n * R; all-reduce: 2(n-1)/n * R (reduce-
    scatter + all-gather phases); reduce-scatter: (n-1) * R (operand is
    n*R); all-to-all: (n-1)/n * R; collective-permute: R.
    """
    if not shapes:
        return 0.0
    if kind == "all-gather":
        r = max(shapes)
        return (n - 1) / n * r
    r = shapes[-1]
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * r
    if kind == "reduce-scatter":
        return float(n - 1) * r
    if kind == "all-to-all":
        return (n - 1) / n * r
    return float(max(shapes))      # collective-permute


def parse_hlo_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-chip collective wire bytes per kind, parsed from optimized HLO.

    Async ``-start``/``-done`` pairs are counted once (on the -start).
    """
    out: Dict[str, Dict[str, float]] = {
        k: {"bytes": 0.0, "count": 0} for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        result_shapes, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue
        b = _wire_bytes(kind, _shapes(result_shapes), _group_size(line))
        out[kind]["bytes"] += b
        out[kind]["count"] += 1
    return out


def collective_bytes(hlo_text: str) -> float:
    per = parse_hlo_collectives(hlo_text)
    return float(sum(v["bytes"] for v in per.values()))


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                 # per-chip program FLOPs
    hlo_bytes: float                 # per-chip HBM traffic
    coll_bytes: float                # per-chip collective payload
    model_flops: float               # analytic 6*N*D (global, per step)
    compute_s: float
    memory_s: float
    collective_s: float
    bytes_per_device: float = 0.0    # from memory_analysis (fit check)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / (global HLO FLOPs) — remat/redundancy waste."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved if the step ran at the
        max-term time: useful compute time / roofline step time."""
        t_useful = self.model_flops / (self.chips * HW.peak_flops)
        return t_useful / self.step_time_s if self.step_time_s > 0 else 0.0

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_ms": round(self.compute_s * 1e3, 3),
            "memory_ms": round(self.memory_s * 1e3, 3),
            "collective_ms": round(self.collective_s * 1e3, 3),
            "dominant": self.dominant,
            "step_ms": round(self.step_time_s * 1e3, 3),
            "useful_flops_frac": round(self.useful_flops_fraction, 4),
            "roofline_frac": round(self.roofline_fraction, 4),
            "bytes_per_device_gb": round(self.bytes_per_device / 1e9, 3),
        }


def roofline_terms(*, arch: str, shape: str, mesh: str, chips: int,
                   hlo_flops: float, hlo_bytes: float, coll_bytes: float,
                   model_flops: float, bytes_per_device: float = 0.0,
                   hw: Hardware = HW) -> RooflineReport:
    """All inputs are per-chip program quantities (XLA SPMD convention)."""
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes, coll_bytes=coll_bytes,
        model_flops=model_flops,
        compute_s=hlo_flops / hw.peak_flops,
        memory_s=hlo_bytes / hw.hbm_bw,
        collective_s=coll_bytes / hw.link_bw,
        bytes_per_device=bytes_per_device,
    )


# ----------------------------------------------------- streaming kernels ---
# The fused cluster-epoch kernels (kernels/cluster_step.py) do essentially
# no arithmetic per byte — a replay epoch reads the (K, L) lease tables and
# the (K, Q) queue head, and writes them back.  Their roofline is therefore
# one-term: wall time vs. the time the memory system needs to move the
# analytic traffic.  ``bytes_per_launch`` is analytic (summed from the
# operand/result shapes), not measured — the point is a stable,
# host-independent denominator for the CI regression gate.
@dataclasses.dataclass
class KernelRoofline:
    kernel: str                       # e.g. "cluster_epoch_step"
    launches: int
    bytes_per_launch: float           # analytic operand+result traffic
    wall_s: float                     # total wall across all launches
    items: int = 0                    # events (or candidates) processed
    measured_bw: float = 0.0          # host copy bandwidth (CPU baseline)
    hw: Hardware = HW

    @property
    def total_bytes(self) -> float:
        return self.launches * self.bytes_per_launch

    @property
    def achieved_bw(self) -> float:
        """Bytes actually streamed per wall second."""
        return self.total_bytes / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def bound_s(self) -> float:
        """Memory-bound time on the reference accelerator's HBM."""
        return self.total_bytes / self.hw.hbm_bw

    @property
    def bound_fraction(self) -> float:
        """Fraction of the memory roofline achieved.  On the CPU container
        this is tiny (launch overhead dominates the small tables); compare
        against ``measured_bw`` for the host-relative number."""
        return self.bound_s / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def host_fraction(self) -> float:
        """achieved_bw / measured host copy bandwidth (0 if unmeasured)."""
        if self.measured_bw <= 0:
            return 0.0
        return self.achieved_bw / self.measured_bw

    def row(self) -> Dict:
        return {
            "kernel": self.kernel,
            "launches": self.launches,
            "bytes_per_launch": int(self.bytes_per_launch),
            "total_gb": round(self.total_bytes / 1e9, 4),
            "wall_s": round(self.wall_s, 4),
            "items": self.items,
            "items_per_s": (round(self.items / self.wall_s, 1)
                            if self.wall_s > 0 else None),
            "achieved_gb_s": round(self.achieved_bw / 1e9, 4),
            "hbm_bound_frac": round(self.bound_fraction, 6),
            "host_bw_frac": round(self.host_fraction, 4),
            "tpu_projected_s": round(self.bound_s, 6),
        }


def kernel_roofline(kernel: str, *, launches: int, bytes_per_launch: float,
                    wall_s: float, items: int = 0, measured_bw: float = 0.0,
                    hw: Hardware = HW) -> KernelRoofline:
    return KernelRoofline(kernel=kernel, launches=launches,
                          bytes_per_launch=bytes_per_launch, wall_s=wall_s,
                          items=items, measured_bw=measured_bw, hw=hw)


def host_copy_bandwidth(n_bytes: int = 1 << 26, reps: int = 3) -> float:
    """Measured host memcpy bandwidth (bytes/s, read+write counted once):
    the honest local ceiling for a streaming kernel on this container."""
    import time

    import numpy as np
    src = np.ones(n_bytes // 8, np.float64)
    dst = np.empty_like(src)
    np.copyto(dst, src)                       # touch both buffers
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        best = min(best, time.perf_counter() - t0)
    return src.nbytes / best
