"""Analytic MODEL_FLOPS per step: 6*N*D (train) / 2*N*D (inference forward),
with N = active parameter count (MoE: top-k experts only) and D = tokens
processed by the step. The §Roofline "useful compute" yardstick."""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig

__all__ = ["model_flops"]


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        # encoder consumes S frames; decoder consumes DEC_LEN (448) tokens.
        from repro.models.encdec import DEC_LEN
        d, f = cfg.d_model, cfg.d_ff
        enc_per_layer = 4 * d * d + 2 * d * f
        n_enc = cfg.encoder_layers * enc_per_layer
        n_dec = n_active - n_enc
        mult = 6.0 if shape.kind == "train" else 2.0
        if shape.kind == "decode":
            return 2.0 * n_dec * B
        return mult * (n_enc * B * S + n_dec * B * min(DEC_LEN, S))
    if shape.kind == "train":
        return 6.0 * n_active * B * S
    if shape.kind == "prefill":
        return 2.0 * n_active * B * S
    # decode: one new token per sequence against the cache
    return 2.0 * n_active * B
